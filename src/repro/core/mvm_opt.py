"""MVM-grained optimization (§3.3.3, Figure 12).

Inherits the CG-grained results and, under the core-tier abstraction:

  * **VXB-granularity duplication** — Eq. (1):

        D'_Oi = floor( num_core_Oi * D_Oi * Core_VXB / num_VXB_Oi )

    The CG pass allocated whole cores; at crossbar granularity those
    cores contain ``Core_VXB`` VXB slots each, so the copy count is
    re-derived from the *slot* pool rather than the core pool.

  * **Staggered MVM pipeline** — instead of waiting until *all* crossbars
    of a VXB set receive their inputs (traditional scheduling, Fig.12(c)),
    a crossbar is activated as soon as its input arrives (Fig.12(d)).
    Effects (realized in cimsim.perf):
      - peak concurrently-active crossbars drop from the full VXB set to
        one row-stripe of it (peak-power reduction, e.g. PUMA -75%);
      - inter-stage transfers shrink to half-tile granularity, halving
        per-stage communication and the pipeline fill latency.
"""
from __future__ import annotations

import math

from .abstraction import ComputingMode
from .cg_opt import SchedulePlan, balance_duplication
from .mapping import vxbs_per_core


def run(plan: SchedulePlan) -> SchedulePlan:
    arch = plan.arch
    if not arch.mode.allows(ComputingMode.XBM):
        raise ValueError(f"{arch.name} exposes no crossbar-level interface "
                         f"(mode={arch.mode.value})")

    for seg in plan.segments:
        # The CG pass allocated whole cores; XBM exposes the crossbars
        # inside them, so the slot pool of this segment is every crossbar
        # of every allocated core.  (CM cannot see a core's idle
        # crossbars — e.g. an operator whose matrix needs 4 of the 8
        # arrays wastes half the core; XBM packs a second copy there,
        # which is exactly the §3.4 walk-through's dup 2 -> 4 update.)
        slot_pool = sum(p.dup * p.cores for p in seg.placements) \
            * arch.core.n_xbs
        for p in seg.placements:
            core_vxb = vxbs_per_core(arch, p.mapping)
            num_vxb = p.mapping.n_vxb
            # Eq. (1) per-operator floor (recorded for reference):
            slots = p.cores * p.dup * core_vxb * p.mapping.xbs_per_vxb
            d_eq1 = max(1, (p.cores * p.dup * core_vxb) // max(num_vxb, 1))
            p.vxb_slots = slots
            p.node.sched.update({"dup_mvm_eq1": d_eq1, "vxb_slots": slots,
                                 "core_vxb": core_vxb, "num_vxb": num_vxb})
            p.dup = min(d_eq1, p.n_mvm) if not plan.use_duplication else p.dup

        if plan.use_duplication:
            # joint re-balance over the segment's crossbar-slot pool
            # (subsumes Eq.(1): every op gets at least its Eq.(1) floor
            # when slots allow, and freed fractional-core waste is
            # redistributed to the bottleneck stages).
            if plan.use_pipeline:
                balance_duplication(seg.placements, slot_pool, unit="xbs")
            else:
                from .cg_opt import greedy_duplication
                greedy_duplication(seg.placements, slot_pool, unit="xbs")
        for p in seg.placements:
            p.node.sched["dup_mvm"] = p.dup

    plan.mvm_pipeline = True
    plan.notes["mvm_stagger"] = True
    return plan


def peak_active_xbs(p, staggered: bool) -> int:
    """Crossbars of one placement active in the same cycle.

    Traditional scheduling fires every crossbar of every copy at once;
    the staggered pipeline keeps only one row-stripe (``grid_c`` crossbars
    x bit-slice group) of each copy active per cycle (Figure 12(d): 4 of
    6 VXBs -> here modeled as ceil(n_xbs / grid_r))."""
    per_copy = p.mapping.n_xbs
    if staggered and p.mapping.grid_r > 1:
        per_copy = math.ceil(p.mapping.n_xbs / p.mapping.grid_r)
    return p.dup * per_copy
