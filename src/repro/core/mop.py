"""Meta-operator flow (§3.3 code generation, Figures 10/11/13/15).

The compiler's output is a *meta-operator flow*: CIM activation operators
(per computing mode), digital-compute operators (DCOM) and data-movement
operators (DMOV), optionally wrapped in ``parallel { }`` blocks.  The BNF
of Figure 10:

    <code>      ::= <operators>* | parallel "{" <operators>* "}"
    <operators> ::= <operators>* <CIM>* <DCOM>* <DMOV>*
    <CIM>       ::= MOP_CM | MOP_XBM | MOP_WLM
    <MOP_CM>    ::= cim.read_core(op, params, core_addr, src, dst)
    <MOP_XBM>   ::= cim.read_xb(xb_addr, len) | cim.write_xb(xb_addr, mat)
    <MOP_WLM>   ::= cim.read_row(row_addr, len) | cim.write_row(row_addr, value)
    <DCOM>      ::= Relu(src,dst,len) | add(src1,src2,dst,len) | ...
    <DMOV>      ::= mov(src,dst,len)

We keep the flow *structured* (dataclasses with attribute dicts) so that
(a) the functional simulator can interpret it, (b) the perf simulator can
cost it, and (c) ``to_text`` emits the paper's concrete syntax.  Large
flows use ``Loop`` compression ("256 similar code segments" in §3.4) —
``expand()`` materializes them for the interpreter.

Users may extend the DCOM vocabulary (paper: "users have the flexibility
to extend meta operators") via ``register_dcom``.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Any, Dict, Iterator, List, Optional, Sequence, Union

MOP_CM = {"cim.read_core"}
MOP_XBM = {"cim.read_xb", "cim.write_xb"}
MOP_WLM = {"cim.read_row", "cim.write_row"}
CIM_KINDS = MOP_CM | MOP_XBM | MOP_WLM
DMOV_KINDS = {"mov"}
DCOM_KINDS = {
    "relu", "gelu", "silu", "sigmoid", "tanh", "add", "mul", "shift_acc",
    "maxpool", "avgpool", "softmax", "layernorm", "rmsnorm", "matmul",
    "embedding", "ssm_scan", "rope", "topk_router", "softcap", "identity",
    "transpose", "concat", "split", "flatten", "reshape",
}


def register_dcom(kind: str) -> None:
    """Extend the DCOM meta-operator vocabulary (hardware-defined ops)."""
    DCOM_KINDS.add(kind)


@dataclasses.dataclass
class MetaOp:
    kind: str
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in CIM_KINDS | DMOV_KINDS | DCOM_KINDS:
            raise ValueError(f"unknown meta-operator kind {self.kind!r}")

    @property
    def family(self) -> str:
        if self.kind in CIM_KINDS:
            return "CIM"
        if self.kind in DMOV_KINDS:
            return "DMOV"
        return "DCOM"

    def to_text(self) -> str:
        args = ",".join(f"{k}={_fmt(v)}" for k, v in self.attrs.items()
                        if not k.startswith("_"))
        return f"{self.kind}({args})"


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:g}"
    if isinstance(v, (list, tuple)):
        return "[" + "x".join(str(x) for x in v) + "]"
    return str(v)


@dataclasses.dataclass
class Parallel:
    stmts: List["Stmt"]

    def to_text(self, indent: int = 0) -> str:
        pad = "  " * indent
        inner = "\n".join(_stmt_text(s, indent + 1) for s in self.stmts)
        return f"{pad}parallel {{\n{inner}\n{pad}}}"


@dataclasses.dataclass
class Loop:
    body: List["Stmt"]
    count: int
    note: str = ""

    def to_text(self, indent: int = 0) -> str:
        pad = "  " * indent
        note = f"  // {self.note}" if self.note else ""
        inner = "\n".join(_stmt_text(s, indent + 1) for s in self.body)
        return f"{pad}repeat x{self.count} {{{note}\n{inner}\n{pad}}}"


Stmt = Union[MetaOp, Parallel, Loop]


def _stmt_text(s: Stmt, indent: int) -> str:
    if isinstance(s, MetaOp):
        return "  " * indent + s.to_text()
    return s.to_text(indent)


@dataclasses.dataclass
class Program:
    """A compiled meta-operator flow plus compile-time metadata."""

    name: str
    stmts: List[Stmt]
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_text(self, max_lines: Optional[int] = None) -> str:
        lines: List[str] = [f"// meta-operator flow: {self.name}"]
        for s in self.stmts:
            lines.extend(_stmt_text(s, 0).split("\n"))
            if max_lines and len(lines) > max_lines:
                lines = lines[:max_lines] + ["// ... (truncated)"]
                break
        return "\n".join(lines)

    # -- iteration ---------------------------------------------------------
    def walk(self, expand_loops: bool = False) -> Iterator[MetaOp]:
        yield from _walk(self.stmts, expand_loops)

    def expand(self) -> "Program":
        """Materialize Loop compressions (small programs / interpreter)."""
        return Program(self.name, list(_expand(self.stmts)), dict(self.meta))

    # -- statistics ----------------------------------------------------------
    def op_counts(self, weighted: bool = True) -> Counter:
        c: Counter = Counter()
        _count(self.stmts, 1, c, weighted)
        return c

    def max_parallel_width(self) -> int:
        return _max_width(self.stmts)

    def validate(self) -> None:
        """Structural invariants: known kinds, positive loop counts,
        parallel blocks contain only meta-ops/loops."""
        for op in self.walk(expand_loops=False):
            assert op.kind in CIM_KINDS | DMOV_KINDS | DCOM_KINDS

        def check(stmts: Sequence[Stmt]):
            for s in stmts:
                if isinstance(s, Loop):
                    assert s.count >= 1, "loop count must be >= 1"
                    check(s.body)
                elif isinstance(s, Parallel):
                    assert s.stmts, "empty parallel block"
                    check(s.stmts)

        check(self.stmts)


def _walk(stmts: Sequence[Stmt], expand_loops: bool) -> Iterator[MetaOp]:
    for s in stmts:
        if isinstance(s, MetaOp):
            yield s
        elif isinstance(s, Parallel):
            yield from _walk(s.stmts, expand_loops)
        else:
            reps = s.count if expand_loops else 1
            for _ in range(reps):
                yield from _walk(s.body, expand_loops)


def _expand(stmts: Sequence[Stmt]) -> Iterator[Stmt]:
    for s in stmts:
        if isinstance(s, Loop):
            for _ in range(s.count):
                yield from _expand(s.body)
        elif isinstance(s, Parallel):
            yield Parallel(list(_expand(s.stmts)))
        else:
            yield s


def _count(stmts: Sequence[Stmt], mult: int, c: Counter, weighted: bool):
    for s in stmts:
        if isinstance(s, MetaOp):
            c[s.kind] += mult
        elif isinstance(s, Parallel):
            _count(s.stmts, mult, c, weighted)
        else:
            _count(s.body, mult * (s.count if weighted else 1), c, weighted)


def _max_width(stmts: Sequence[Stmt]) -> int:
    best = 1
    for s in stmts:
        if isinstance(s, Parallel):
            best = max(best, sum(1 for _ in _walk(s.stmts, False)))
            best = max(best, _max_width(s.stmts))
        elif isinstance(s, Loop):
            best = max(best, _max_width(s.body))
    return best


# -- convenience constructors (paper syntax) ---------------------------------

def read_core(op: str, core_addr: int, src: int, dst: int, **kw) -> MetaOp:
    return MetaOp("cim.read_core", dict(op=op, core_addr=core_addr,
                                        src=src, dst=dst, **kw))


def write_xb(xb_addr: Any, mat: Any, **kw) -> MetaOp:
    return MetaOp("cim.write_xb", dict(xb_addr=xb_addr, mat=mat, **kw))


def read_xb(xb_addr: Any, length: int = 1, **kw) -> MetaOp:
    return MetaOp("cim.read_xb", dict(xb_addr=xb_addr, len=length, **kw))


def write_row(row_addr: Any, value: Any, **kw) -> MetaOp:
    return MetaOp("cim.write_row", dict(row_addr=row_addr, value=value, **kw))


def read_row(row_addr: Any, length: int, **kw) -> MetaOp:
    return MetaOp("cim.read_row", dict(row_addr=row_addr, len=length, **kw))


def mov(src: Any, dst: Any, length: int, **kw) -> MetaOp:
    return MetaOp("mov", dict(src=src, dst=dst, len=length, **kw))


def dcom(kind: str, **kw) -> MetaOp:
    return MetaOp(kind, kw)
