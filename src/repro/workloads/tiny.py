"""Tiny graphs for the functional simulator and unit tests."""
from __future__ import annotations

from ..core.graph import Graph, Node


def conv_relu_toy() -> Graph:
    """The §3.4 walk-through workload: Conv(32,3,3,3) s=1 p=1 + ReLU on a
    3x32x32 input."""
    nodes = [
        Node("conv", "Conv", ["input"], ["conv.out"],
             {"weight_shape": (32, 3, 3, 3), "stride": 1, "pad": 1}),
        Node("relu", "Relu", ["conv.out"], ["relu.out"]),
    ]
    return Graph("conv_relu_toy", nodes, {"input": (3, 32, 32)}, ["relu.out"])


def tiny_cnn(in_hw: int = 8, c1: int = 4, c2: int = 8,
             n_classes: int = 10) -> Graph:
    nodes = [
        Node("conv1", "Conv", ["input"], ["conv1.out"],
             {"weight_shape": (c1, 3, 3, 3), "stride": 1, "pad": 1}),
        Node("relu1", "Relu", ["conv1.out"], ["relu1.out"]),
        Node("conv2", "Conv", ["relu1.out"], ["conv2.out"],
             {"weight_shape": (c2, c1, 3, 3), "stride": 1, "pad": 1}),
        Node("relu2", "Relu", ["conv2.out"], ["relu2.out"]),
        Node("pool", "MaxPool", ["relu2.out"], ["pool.out"],
             {"kernel": 2, "stride": 2}),
        Node("flatten", "Flatten", ["pool.out"], ["flat.out"]),
        Node("fc", "Gemm", ["flat.out"], ["fc.out"],
             {"weight_shape": (c2 * (in_hw // 2) ** 2, n_classes)}),
    ]
    return Graph("tiny_cnn", nodes, {"input": (3, in_hw, in_hw)}, ["fc.out"])


def tiny_mlp(d_in: int = 16, d_h: int = 32, d_out: int = 8) -> Graph:
    nodes = [
        Node("fc1", "Gemm", ["input"], ["fc1.out"],
             {"weight_shape": (d_in, d_h)}),
        Node("relu", "Relu", ["fc1.out"], ["relu.out"]),
        Node("fc2", "Gemm", ["relu.out"], ["fc2.out"],
             {"weight_shape": (d_h, d_out)}),
    ]
    return Graph("tiny_mlp", nodes, {"input": (d_in,)}, ["fc2.out"])
