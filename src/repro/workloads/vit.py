"""ViT (Dosovitskiy et al.) computation graph — §4.4 sensitivity benchmark.

Transformer blocks expose the CIM-applicability split: Q/K/V/O and MLP
projections are weight-stationary Gemms (crossbar-mappable), while QK^T
and AV are activation x activation MatMuls that execute on the ALU —
exactly the distinction CIM-MLC's meta-operator flow records.
"""
from __future__ import annotations

from typing import List

from ..core.graph import Graph, Node


def vit_base(n_layers: int = 12, d: int = 768, n_heads: int = 12,
             d_ff: int = 3072, n_tokens: int = 197,
             n_classes: int = 1000) -> Graph:
    nodes: List[Node] = []
    t = "tokens"   # (n_tokens, d) patch embeddings

    def gemm(name, tin, cin, cout):
        nodes.append(Node(name, "Gemm", [tin], [f"{name}.out"],
                          {"weight_shape": (cin, cout)}))
        return f"{name}.out"

    for l in range(n_layers):
        p = f"l{l}."
        ln1 = f"{p}ln1.out"
        nodes.append(Node(f"{p}ln1", "LayerNorm", [t], [ln1]))
        q = gemm(f"{p}wq", ln1, d, d)
        k = gemm(f"{p}wk", ln1, d, d)
        v = gemm(f"{p}wv", ln1, d, d)
        nodes.append(Node(f"{p}qkt", "MatMul", [q, k], [f"{p}qkt.out"],
                          {"transpose_b": True}))
        nodes.append(Node(f"{p}smax", "Softmax", [f"{p}qkt.out"],
                          [f"{p}smax.out"]))
        nodes.append(Node(f"{p}av", "MatMul", [f"{p}smax.out", v],
                          [f"{p}av.out"]))
        o = gemm(f"{p}wo", f"{p}av.out", d, d)
        nodes.append(Node(f"{p}res1", "Add", [t, o], [f"{p}res1.out"]))
        t = f"{p}res1.out"
        ln2 = f"{p}ln2.out"
        nodes.append(Node(f"{p}ln2", "LayerNorm", [t], [ln2]))
        h = gemm(f"{p}fc1", ln2, d, d_ff)
        nodes.append(Node(f"{p}gelu", "Gelu", [h], [f"{p}gelu.out"]))
        h2 = gemm(f"{p}fc2", f"{p}gelu.out", d_ff, d)
        nodes.append(Node(f"{p}res2", "Add", [t, h2], [f"{p}res2.out"]))
        t = f"{p}res2.out"

    nodes.append(Node("ln_f", "LayerNorm", [t], ["ln_f.out"]))
    head = Node("head", "Gemm", ["ln_f.out"], ["head.out"],
                {"weight_shape": (d, n_classes)})
    nodes.append(head)
    return Graph("vit", nodes, {"tokens": (n_tokens, d)}, ["head.out"])
