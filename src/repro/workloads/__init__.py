"""CIM benchmark networks (§4.1 "Network Benchmark") as graph builders."""
from .vgg import vgg7, vgg16
from .resnet import resnet18, resnet34, resnet50, resnet101
from .vit import vit_base
from .tiny import tiny_cnn, tiny_mlp, conv_relu_toy

WORKLOADS = {
    "vgg7": vgg7,
    "vgg16": vgg16,
    "resnet18": resnet18,
    "resnet34": resnet34,
    "resnet50": resnet50,
    "resnet101": resnet101,
    "vit": vit_base,
    "tiny_cnn": tiny_cnn,
    "tiny_mlp": tiny_mlp,
    "conv_relu_toy": conv_relu_toy,
}


def get_workload(name: str, **kw):
    if name.startswith("lmblock:"):
        from .lm_blocks import lm_block
        return lm_block(name.split(":", 1)[1], **kw)
    if name not in WORKLOADS:
        raise KeyError(f"unknown workload {name!r}; have {sorted(WORKLOADS)}")
    return WORKLOADS[name](**kw)
