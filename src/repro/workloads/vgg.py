"""VGG series (Simonyan & Zisserman) as computation graphs.

VGG16 is the PUMA comparison benchmark (Fig. 20(b)); VGG7 is the Jain et
al. comparison benchmark (Fig. 20(c)).  Graphs are single-image (batch=1,
CHW tensors) 8-bit inference graphs, matching §4.1.
"""
from __future__ import annotations

from typing import List, Tuple

from ..core.graph import Graph, Node


def _conv_block(nodes: List[Node], idx: int, tin: str, cin: int, cout: int,
                k: int = 3, pad: int = 1, stride: int = 1) -> Tuple[int, str]:
    conv = f"conv{idx}"
    nodes.append(Node(conv, "Conv", [tin], [f"{conv}.out"],
                      {"weight_shape": (cout, cin, k, k),
                       "stride": stride, "pad": pad}))
    nodes.append(Node(f"relu{idx}", "Relu", [f"{conv}.out"],
                      [f"relu{idx}.out"]))
    return idx + 1, f"relu{idx}.out"


def _pool(nodes: List[Node], idx: int, tin: str) -> Tuple[int, str]:
    nodes.append(Node(f"pool{idx}", "MaxPool", [tin], [f"pool{idx}.out"],
                      {"kernel": 2, "stride": 2}))
    return idx + 1, f"pool{idx}.out"


def _vgg(name: str, cfg, in_hw: int, fcs, n_classes: int) -> Graph:
    nodes: List[Node] = []
    t = "input"
    cin = 3
    ci, pi = 0, 0
    for entry in cfg:
        if entry == "M":
            pi, t = _pool(nodes, pi, t)
        else:
            ci, t = _conv_block(nodes, ci, t, cin, entry)
            cin = entry
    nodes.append(Node("flatten", "Flatten", [t], ["flat.out"]))
    t = "flat.out"
    for i, width in enumerate(fcs + [n_classes]):
        fc = f"fc{i}"
        # Flatten output dimension is inferred at shape-inference time;
        # record -1 and fix up below.
        nodes.append(Node(fc, "Gemm", [t], [f"{fc}.out"],
                          {"weight_shape": (-1, width)}))
        if i < len(fcs):
            nodes.append(Node(f"fcrelu{i}", "Relu", [f"{fc}.out"],
                              [f"fcrelu{i}.out"]))
            t = f"fcrelu{i}.out"
        else:
            t = f"{fc}.out"

    g = _finalize(name, nodes, (3, in_hw, in_hw), t)
    return g


def _finalize(name: str, nodes: List[Node], in_shape, out_tensor) -> Graph:
    """Resolve -1 Gemm input dims using shape inference."""
    # first pass with placeholder to compute flatten dims
    shapes = {"input": in_shape}
    from ..core.graph import infer_node_shape
    for n in nodes:
        if n.op_type in ("Gemm", "Linear") and n.attrs["weight_shape"][0] == -1:
            cin = shapes[n.inputs[0]][-1]
            n.attrs["weight_shape"] = (cin, n.attrs["weight_shape"][1])
        infer_node_shape(n, shapes)
    return Graph(name, nodes, {"input": tuple(in_shape)}, [out_tensor])


def vgg16(n_classes: int = 1000, in_hw: int = 224) -> Graph:
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]
    return _vgg("vgg16", cfg, in_hw, [4096, 4096], n_classes)


def vgg7(n_classes: int = 10, in_hw: int = 32) -> Graph:
    """VGG7 (6 conv + 1 fc), the standard CIFAR-scale benchmark used for
    CIM macro evaluations (Jain et al. comparison)."""
    cfg = [128, 128, "M", 256, 256, "M", 512, 512, "M"]
    return _vgg("vgg7", cfg, in_hw, [1024], n_classes)
