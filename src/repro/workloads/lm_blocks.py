"""Assigned-LM-architecture blocks as CIM workloads (DESIGN.md §4).

Builds the computation graph of one decoder block of any assigned
architecture so the CIM-MLC compiler can schedule it: weight-stationary
projections (Q/K/V/O, MLP, expert FFNs, SSM in/out projections) map to
crossbars; attention QK^T/AV MatMuls, softmax, routing and the SSD scan
are ALU (DCOM) operators — the weight-stationary applicability split.
"""
from __future__ import annotations

from typing import List

from ..configs import get_config
from ..core.graph import Graph, Node


def lm_block(arch: str, seq: int = 512) -> Graph:
    cfg = get_config(arch)
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    nodes: List[Node] = []
    t = "x"

    def gemm(name, tin, cin, cout):
        nodes.append(Node(name, "Gemm", [tin], [f"{name}.out"],
                          {"weight_shape": (cin, cout)}))
        return f"{name}.out"

    spec = cfg.unit[0]
    nodes.append(Node("ln1", "RMSNorm", [t], ["ln1.out"]))
    t_in = "ln1.out"

    if spec.mixer in ("attn", "hybrid", "mla"):
        if spec.mixer == "mla":
            q = gemm("wq", t_in, d, h * (cfg.qk_nope_dim + cfg.qk_rope_dim))
            ckv = gemm("w_dkv", t_in, d, cfg.kv_lora + cfg.qk_rope_dim)
            kk = gemm("w_uk", ckv, cfg.kv_lora + cfg.qk_rope_dim,
                      h * cfg.qk_nope_dim)
            v = gemm("w_uv", ckv, cfg.kv_lora + cfg.qk_rope_dim,
                     h * cfg.v_head_dim)
            att_dim = h * cfg.v_head_dim
        else:
            q = gemm("wq", t_in, d, h * hd)
            kk = gemm("wk", t_in, d, k * hd)
            v = gemm("wv", t_in, d, k * hd)
            att_dim = h * hd
        nodes.append(Node("qkt", "MatMul", [q, kk], ["qkt.out"],
                          {"transpose_b": True}))
        nodes.append(Node("smax", "Softmax", ["qkt.out"], ["smax.out"]))
        nodes.append(Node("av", "MatMul", ["smax.out", v], ["av.out"]))
        o = gemm("wo", "av.out", att_dim, d)
        nodes.append(Node("res1", "Add", [t, o], ["res1.out"]))
        t = "res1.out"

    if spec.mixer in ("ssm", "hybrid"):
        di = cfg.d_inner
        xs = gemm("w_x", t_in, d, di)
        nodes.append(Node("ssd", "SSMScan", [xs], ["ssd.out"]))
        op = gemm("out_proj", "ssd.out", di, d)
        nodes.append(Node("res_s", "Add", [t, op], ["res_s.out"]))
        t = "res_s.out"

    if spec.mlp != "none":
        nodes.append(Node("ln2", "RMSNorm", [t], ["ln2.out"]))
        if spec.mlp == "moe":
            nodes.append(Node("router", "TopKRouter", ["ln2.out"],
                              ["router.out"],
                              {"n_experts": cfg.n_experts}))
            outs = []
            for e in range(cfg.n_experts):
                hh = gemm(f"e{e}_wi", "ln2.out", d, cfg.moe_d_ff)
                nodes.append(Node(f"e{e}_act", "Silu", [hh],
                                  [f"e{e}_act.out"]))
                outs.append(gemm(f"e{e}_wo", f"e{e}_act.out",
                                 cfg.moe_d_ff, d))
            acc = outs[0]
            for e, o in enumerate(outs[1:], 1):
                nodes.append(Node(f"moe_add{e}", "Add", [acc, o],
                                  [f"moe_add{e}.out"]))
                acc = f"moe_add{e}.out"
            y = acc
        else:
            hh = gemm("wi", "ln2.out", d, cfg.d_ff)
            nodes.append(Node("act", "Gelu" if cfg.act == "gelu" else "Silu",
                              [hh], ["act.out"]))
            y = gemm("wo_mlp", "act.out", cfg.d_ff, d)
        nodes.append(Node("res2", "Add", [t, y], ["res2.out"]))
        t = "res2.out"

    return Graph(f"lmblock-{arch}", nodes, {"x": (seq, d)}, [t])
