"""ResNet series (He et al.) computation graphs — §4.3 benchmark."""
from __future__ import annotations

from typing import List

from ..core.graph import Graph, Node


class _B:
    def __init__(self):
        self.nodes: List[Node] = []
        self.i = 0

    def conv(self, tin: str, cin: int, cout: int, k: int, stride: int = 1,
             pad: int = None, relu: bool = True) -> str:
        if pad is None:
            pad = k // 2
        self.i += 1
        name = f"conv{self.i}"
        self.nodes.append(Node(name, "Conv", [tin], [f"{name}.out"],
                               {"weight_shape": (cout, cin, k, k),
                                "stride": stride, "pad": pad}))
        t = f"{name}.out"
        if relu:
            self.nodes.append(Node(f"relu{self.i}", "Relu", [t],
                                   [f"relu{self.i}.out"]))
            t = f"relu{self.i}.out"
        return t

    def add(self, a: str, b: str, relu: bool = True) -> str:
        self.i += 1
        name = f"add{self.i}"
        self.nodes.append(Node(name, "Add", [a, b], [f"{name}.out"]))
        t = f"{name}.out"
        if relu:
            self.nodes.append(Node(f"relu{self.i}", "Relu", [t],
                                   [f"relu{self.i}.out"]))
            t = f"relu{self.i}.out"
        return t

    def pool(self, tin: str, kind: str = "MaxPool", k: int = 3,
             stride: int = 2, pad: int = 1) -> str:
        self.i += 1
        name = f"pool{self.i}"
        self.nodes.append(Node(name, kind, [tin], [f"{name}.out"],
                               {"kernel": k, "stride": stride, "pad": pad}))
        return f"{name}.out"


def _basic_block(b: _B, tin: str, cin: int, cout: int, stride: int) -> str:
    t = b.conv(tin, cin, cout, 3, stride)
    t = b.conv(t, cout, cout, 3, 1, relu=False)
    if stride != 1 or cin != cout:
        sc = b.conv(tin, cin, cout, 1, stride, pad=0, relu=False)
    else:
        sc = tin
    return b.add(t, sc)


def _bottleneck(b: _B, tin: str, cin: int, cmid: int, stride: int) -> str:
    cout = cmid * 4
    t = b.conv(tin, cin, cmid, 1, 1, pad=0)
    t = b.conv(t, cmid, cmid, 3, stride)
    t = b.conv(t, cmid, cout, 1, 1, pad=0, relu=False)
    if stride != 1 or cin != cout:
        sc = b.conv(tin, cin, cout, 1, stride, pad=0, relu=False)
    else:
        sc = tin
    return b.add(t, sc)


def _resnet(name: str, layers, bottleneck: bool, n_classes: int = 1000,
            in_hw: int = 224) -> Graph:
    b = _B()
    t = b.conv("input", 3, 64, 7, 2, pad=3)
    t = b.pool(t)
    cin = 64
    for stage, (n_blocks, cmid) in enumerate(zip(layers, (64, 128, 256, 512))):
        for blk in range(n_blocks):
            stride = 2 if (stage > 0 and blk == 0) else 1
            if bottleneck:
                t = _bottleneck(b, t, cin, cmid, stride)
                cin = cmid * 4
            else:
                t = _basic_block(b, t, cin, cmid, stride)
                cin = cmid
    t_gap = "gap.out"
    b.nodes.append(Node("gap", "GlobalAveragePool", [t], [t_gap]))
    b.nodes.append(Node("flatten", "Flatten", [t_gap], ["flat.out"]))
    b.nodes.append(Node("fc", "Gemm", ["flat.out"], ["fc.out"],
                        {"weight_shape": (cin, n_classes)}))
    return Graph(name, b.nodes, {"input": (3, in_hw, in_hw)}, ["fc.out"])


def resnet18(**kw) -> Graph:
    return _resnet("resnet18", (2, 2, 2, 2), False, **kw)


def resnet34(**kw) -> Graph:
    return _resnet("resnet34", (3, 4, 6, 3), False, **kw)


def resnet50(**kw) -> Graph:
    return _resnet("resnet50", (3, 4, 6, 3), True, **kw)


def resnet101(**kw) -> Graph:
    return _resnet("resnet101", (3, 4, 23, 3), True, **kw)
