"""Roofline analysis from compiled dry-run artifacts (deliverable g).

``compiled.cost_analysis()`` on the CPU backend counts every while-loop
body ONCE (verified empirically — flops are identical for 5 and 10 scan
iterations), and jax's scan-stacked layers live in while loops.  So this
module walks the post-optimization HLO text itself:

  * builds a per-computation instruction table (name -> dtype/shape),
  * multiplies while-loop bodies by their ``known_trip_count``,
  * counts MXU FLOPs from ``dot`` ops (2 x prod(out) x contraction),
  * approximates HBM traffic as operand+output bytes of top-level
    (post-fusion) instructions,
  * sums collective wire bytes with a ring model:
       all-reduce       2 * size * (g-1)/g
       all-gather       out  * (g-1)/g
       reduce-scatter   out  * (g-1)          (input = g * output)
       all-to-all       size * (g-1)/g
       collective-permute size

All quantities are per-device (the SPMD module is per-partition), so

    compute_term    = flops / PEAK_FLOPS
    memory_term     = hbm_bytes / HBM_BW
    collective_term = wire_bytes / ICI_BW

are per-chip seconds directly; `x chips` in the spec formula cancels
because the parsed module is already the per-chip slice.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
# type is either a (...)-tuple (never contains parens inside; may contain
# '=' in /*index=N*/ comments) or a single token like f32[16,64]{0,1}
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")


def _parse_shape(txt: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """'f32[16,64]{0,1}' or '(f32[..], s32[..])' -> [(dtype, shape), ...]"""
    out = []
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES and dt != "token":
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(shapes: List[Tuple[str, Tuple[int, ...]]]) -> int:
    total = 0
    for dt, shape in shapes:
        total += _DTYPE_BYTES.get(dt, 4) * int(math.prod(shape) or 1)
    return total


class Instr:
    __slots__ = ("name", "shapes", "op", "rest")

    def __init__(self, name, shapes, op, rest):
        self.name, self.shapes, self.op, self.rest = name, shapes, op, rest


def parse_computations(hlo: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m and ("->" in line or line.startswith("ENTRY")
                      or line.rstrip().endswith("{")):
                name = m.group(1)
                comps[name] = []
                cur = name
            continue
        if line.startswith("}") or line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(Instr(m.group(1), _parse_shape(m.group(2)),
                                    m.group(3), m.group(4)))
    return comps


def _group_size(rest: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", rest)
    if m:
        return len(m.group(1).split(","))
    return default


def _trip_count(rest: str) -> int:
    m = re.search(r'known_trip_count[^0-9]*(\d+)', rest)
    return int(m.group(1)) if m else 1


def _called(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w\.\-]+)", rest)
    return m.group(1) if m else None


_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota"}


class HloCost:
    """Recursive per-computation cost with while-trip multiplication."""

    def __init__(self, hlo: str, n_partitions: int):
        self.comps = parse_computations(hlo)
        self.n = n_partitions
        self._memo: Dict[str, Dict[str, float]] = {}
        entry = None
        for line in hlo.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_RE.match(line)
                entry = m.group(1) if m else None
                break
        self.entry = entry or next(iter(self.comps), None)
        # name -> shapes within each computation, for dot operand lookup
        self._shapes: Dict[str, Dict[str, List]] = {
            c: {i.name: i.shapes for i in instrs}
            for c, instrs in self.comps.items()}

    def cost(self, comp: Optional[str] = None) -> Dict[str, float]:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        tot = defaultdict(float)
        self._memo[comp] = tot   # break cycles defensively
        shapes = self._shapes.get(comp, {})
        for ins in self.comps.get(comp, []):
            if ins.op == "while":
                body = _called(ins.rest, "body")
                trip = _trip_count(ins.rest)
                if body:
                    sub = self.cost(body)
                    for k, v in sub.items():
                        tot[k] += v * trip
                cond = _called(ins.rest, "condition")
                if cond:
                    for k, v in self.cost(cond).items():
                        tot[k] += v * trip
                continue
            if ins.op in ("call", "conditional", "async-start"):
                callee = _called(ins.rest, "to_apply") \
                    or _called(ins.rest, "calls")
                if callee:
                    for k, v in self.cost(callee).items():
                        tot[k] += v
                continue
            if ins.op == "fusion":
                # count the fusion's external memory traffic here, plus
                # any dot FLOPs living inside the fused computation
                tot["hbm_bytes"] += self._fusion_bytes(ins, shapes)
                callee = _called(ins.rest, "calls")
                if callee:
                    tot["flops"] += self.cost(callee).get("flops", 0.0)
                continue
            base_op = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base_op in COLLECTIVES:
                out_b = _nbytes(ins.shapes)
                g = _group_size(ins.rest, self.n)
                frac = (g - 1) / max(g, 1)
                if base_op == "all-reduce":
                    wire = 2 * out_b * frac
                elif base_op == "all-gather":
                    wire = out_b * frac
                elif base_op == "reduce-scatter":
                    wire = out_b * (g - 1)
                elif base_op == "all-to-all":
                    wire = out_b * frac
                else:  # collective-permute
                    wire = out_b
                tot["coll_bytes"] += wire
                tot[f"coll:{base_op}"] += wire
                tot["coll_count"] += 1
                tot["hbm_bytes"] += self._io_bytes(ins, shapes)
                continue
            if ins.op in ("dot", "convolution"):
                out_elems = math.prod(ins.shapes[0][1]) if ins.shapes else 0
                k = self._contraction(ins, shapes)
                tot["flops"] += 2.0 * out_elems * k
            if ins.op not in _SKIP_BYTES_OPS:
                tot["hbm_bytes"] += self._io_bytes(ins, shapes)
        self._memo[comp] = dict(tot)
        return self._memo[comp]

    def _contraction(self, ins: Instr, shapes) -> int:
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
        args = re.findall(r"%([\w\.\-]+)", ins.rest)
        if not m or not args:
            return 1
        lhs = shapes.get(args[0])
        if not lhs:
            return 1
        dims = [int(d) for d in m.group(1).split(",") if d]
        shape = lhs[0][1]
        k = 1
        for d in dims:
            if d < len(shape):
                k *= shape[d]
        return k

    def _fusion_bytes(self, ins: Instr, shapes) -> int:
        """Fusion HBM traffic = output + operands, but operands that are
        dynamic-sliced / gathered INSIDE the fused computation only pay
        the slice size (XLA fuses the slice into the consumer, so the
        full buffer is never streamed)."""
        callee = _called(ins.rest, "calls")
        operands = [a for a in re.findall(
            r"%([\w\.\-]+)", ins.rest.split("),")[0]) if a in shapes]
        sliced: Dict[int, int] = {}
        out_b = _nbytes(ins.shapes)
        if callee and callee in self.comps:
            param_idx: Dict[str, int] = {}
            callee_shapes = self._shapes.get(callee, {})
            for ci in self.comps[callee]:
                if ci.op == "parameter":
                    m = re.match(r"(\d+)", ci.rest)
                    if m:
                        param_idx[ci.name] = int(m.group(1))
            for ci in self.comps[callee]:
                args = re.findall(r"%([\w\.\-]+)",
                                  ci.rest.split("metadata")[0])
                if ci.op in ("dynamic-slice", "gather"):
                    if args and args[0] in param_idx:
                        sliced[param_idx[args[0]]] = _nbytes(ci.shapes)
                elif ci.op == "dynamic-update-slice":
                    # in-place residual-stack write: traffic = the update,
                    # not the whole buffer (read side and write side)
                    if args and args[0] in param_idx and len(args) > 1:
                        upd = _nbytes(callee_shapes.get(args[1],
                                                        ci.shapes))
                        idx = param_idx[args[0]]
                        sliced[idx] = upd
                        full = _nbytes(ci.shapes)
                        if out_b >= full:
                            out_b -= full - upd
        b = out_b
        for i, arg in enumerate(operands):
            b += sliced.get(i, _nbytes(shapes[arg]))
        return b

    def _io_bytes(self, ins: Instr, shapes) -> int:
        # sliced accesses touch only the slice, not the whole buffer
        if ins.op in ("dynamic-slice", "gather"):
            return 2 * _nbytes(ins.shapes)
        if ins.op in ("dynamic-update-slice", "scatter"):
            args = re.findall(r"%([\w\.\-]+)",
                              ins.rest.split("metadata")[0])
            upd = shapes.get(args[1]) if len(args) > 1 else None
            return 2 * _nbytes(upd) if upd else 2 * _nbytes(ins.shapes)
        b = _nbytes(ins.shapes)
        for arg in re.findall(r"%([\w\.\-]+)", ins.rest.split("metadata")[0]):
            if arg in shapes:
                b += _nbytes(shapes[arg])
        return b


def parse_collectives(hlo: str, n_partitions: int = 256) -> Dict:
    hc = HloCost(hlo, n_partitions)
    c = hc.cost()
    by_kind = {k.split(":", 1)[1]: v for k, v in c.items()
               if k.startswith("coll:")}
    return {"total_bytes": c.get("coll_bytes", 0.0),
            "count": c.get("coll_count", 0.0),
            "by_kind": by_kind,
            "walked_flops": c.get("flops", 0.0),
            "walked_hbm_bytes": c.get("hbm_bytes", 0.0)}


# ---------------------------------------------------------------------------
# Roofline terms + useful-FLOPs accounting
# ---------------------------------------------------------------------------

def model_params(cfg) -> Tuple[int, int]:
    """(N_total, N_active) parameter counts."""
    import jax
    from ..models import lm
    specs = lm.param_specs(cfg)
    total = sum(math.prod(x.shape) for x in jax.tree.leaves(specs))
    active = total
    if cfg.n_experts and cfg.top_k:
        # routed expert params counted at top_k/E utilization
        e, fm, d = cfg.n_experts, cfg.moe_d_ff, cfg.d_model
        n_moe_layers = sum(1 for s in cfg.unit if s.mlp == "moe") \
            * cfg.n_unit_repeats + sum(1 for s in cfg.pre if s.mlp == "moe")
        routed = n_moe_layers * e * (3 * d * fm)
        active = total - routed + routed * cfg.top_k / e
    return total, int(active)


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs of one step: 6*N*D train, 2*N_active*tokens
    for forward-only (prefill/decode)."""
    n_total, n_active = model_params(cfg)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch          # one token / seq


def terms(rec: Dict, cfg, shape, n_chips: int) -> Dict:
    """Roofline terms (seconds/chip) from a dry-run record."""
    flops = rec.get("walked_flops") or rec.get("flops", 0.0)
    hbm = rec.get("walked_hbm_bytes") or rec.get("bytes_accessed", 0.0)
    coll = rec.get("collective_bytes", 0.0)
    mf = model_flops(cfg, shape)
    out = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm / HBM_BW,
        "collective_s": coll / ICI_BW,
        "model_flops": mf,
        "model_flops_per_chip": mf / n_chips,
        "useful_flops_frac": (mf / n_chips) / flops if flops else 0.0,
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=out.__getitem__)
    out["bottleneck"] = dom.split("_")[0]
    total = max(out["compute_s"], out["memory_s"], out["collective_s"])
    ideal = (mf / n_chips) / PEAK_FLOPS
    out["roofline_frac"] = ideal / total if total > 0 else 0.0
    return out
